"""Serving launcher: batched generation with the static or paged engine.

CPU smoke:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --reduced \
      --batch 4 --new-tokens 16
  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --reduced \
      --paged --block-size 16 --max-batch 4 --mixed --batch 12
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.models import get_model, reduced
from repro.serve import PagedServeEngine, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4,
                    help="number of requests (paged: queue size; static: "
                         "one lockstep batch)")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=None,
                    help="sample from the k highest logits only (fused "
                         "Pallas sampling kernel; needs --temperature > 0)")
    ap.add_argument("--top-p", type=float, default=None,
                    help="nucleus sampling mass cutoff (fused kernel; "
                         "needs --temperature > 0)")
    ap.add_argument("--kv-dtype",
                    choices=["native", "int8", "fp8_e4m3", "fp8_e5m2"],
                    default="native",
                    help="paged KV-cache storage dtype; sub-byte-accurate "
                         "per-row scales ride alongside the pools "
                         "(DESIGN.md §13; paged engine only)")
    ap.add_argument("--init-from", metavar="CKPT", default=None,
                    help="load params from a (possibly differently-"
                         "sharded) training checkpoint directory instead "
                         "of random init — the train->serve handoff "
                         "(DESIGN.md §12).  Accepts a run dir of step_<n> "
                         "checkpoints (latest wins) or one checkpoint dir")
    ap.add_argument("--paged", action="store_true",
                    help="paged KV-cache + continuous batching "
                         "(DESIGN.md §9)")
    ap.add_argument("--block-size", type=int, default=16,
                    help="paged cache block size in tokens")
    ap.add_argument("--max-batch", type=int, default=0,
                    help="decode lanes for the paged engine "
                         "(default: --batch)")
    ap.add_argument("--prefill-chunk", type=int, default=64,
                    help="prompt tokens prefilled per engine step (paged)")
    ap.add_argument("--mixed", action="store_true",
                    help="draw per-request prompt lengths from "
                         "[prompt_len/4, prompt_len] and uneven token "
                         "budgets (the continuous-batching workload)")
    ap.add_argument("--seq-shard", action="store_true",
                    help="sequence-sharded prefill: S over 'model', ring "
                         "attention for full layers (DESIGN.md §8)")
    ap.add_argument("--attn-impl", choices=["auto", "dense", "ring"],
                    default="auto",
                    help="attention implementation selection "
                         "(PerfFlags.attn_impl)")
    ap.add_argument("--admission", choices=["reserve", "optimistic"],
                    default="reserve",
                    help="paged admission: 'reserve' holds worst-case "
                         "blocks per request; 'optimistic' admits on "
                         "prompt fit and preempts on pressure "
                         "(DESIGN.md §14)")
    ap.add_argument("--swap-blocks", type=int, default=0,
                    help="host swap pool capacity in block-equivalents "
                         "(preempted lanes swap KV there; 0 = recompute-"
                         "only preemption)")
    ap.add_argument("--victim-policy",
                    choices=["lowest_priority", "most_blocks", "lifo"],
                    default="lowest_priority",
                    help="which lane preemption evicts first")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request deadline; expired requests end "
                         "TIMEOUT with resources reclaimed (paged)")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="bounded admission queue; overflow is shed with "
                         "a typed rejection, never an exception (paged)")
    ap.add_argument("--shed-policy",
                    choices=["reject_newest", "evict_lowest"],
                    default="reject_newest",
                    help="what a full queue does to the newest arrival")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="record per-request lifecycle spans and write a "
                         "Perfetto / chrome://tracing JSON (DESIGN.md §11)")
    ap.add_argument("--metrics", metavar="PATH", default=None,
                    help="write the final metrics snapshot (TTFT/TPOT/"
                         "queue-wait histograms, pool gauges) as JSONL")
    args = ap.parse_args()

    from repro import obs
    if args.trace:
        obs.enable()

    if args.seq_shard or args.attn_impl != "auto":
        from repro.perf_flags import set_flags
        set_flags(seq_shard=args.seq_shard, attn_impl=args.attn_impl)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    model = get_model(cfg)
    if args.init_from:
        from repro.train import latest_checkpoint, load_checkpoint
        ck = latest_checkpoint(args.init_from) or args.init_from
        restored, step = load_checkpoint(ck)
        params = restored.get("params", restored)
        print(f"params from {ck} (step {step})")
    else:
        params = model.init(jax.random.PRNGKey(0))
    max_len = args.prompt_len + args.new_tokens + 8

    rng = np.random.RandomState(0)
    if args.mixed:
        lens = rng.randint(max(1, args.prompt_len // 4),
                           args.prompt_len + 1, args.batch)
        budgets = list(rng.randint(max(1, args.new_tokens // 4),
                                   args.new_tokens + 1, args.batch))
    else:
        lens = [args.prompt_len] * args.batch
        budgets = [args.new_tokens] * args.batch
    prompts = [list(rng.randint(1, cfg.vocab, L)) for L in lens]
    extra = {}
    if cfg.encoder_layers:
        extra["frames"] = np.asarray(rng.randn(
            args.batch, cfg.frontend_tokens, cfg.frontend_dim), np.float32)
    elif cfg.frontend_tokens:
        extra["patches"] = np.asarray(rng.randn(
            args.batch, cfg.frontend_tokens, cfg.frontend_dim), np.float32)

    if args.paged:
        eng = PagedServeEngine(cfg, params, block_size=args.block_size,
                               max_batch=args.max_batch or args.batch,
                               max_len=max_len,
                               prefill_chunk=args.prefill_chunk,
                               kv_dtype=args.kv_dtype,
                               top_k=args.top_k, top_p=args.top_p,
                               admission=args.admission,
                               swap_blocks=args.swap_blocks,
                               victim_policy=args.victim_policy,
                               max_queue=args.max_queue,
                               shed_policy=args.shed_policy)
        deadlines = ([args.deadline_ms] * len(prompts)
                     if args.deadline_ms is not None else None)
        outs, stats = eng.generate(prompts, max_new_tokens=budgets,
                                   temperature=args.temperature,
                                   deadlines_ms=deadlines)
        by = {}
        for res in eng.results.values():
            by[res.status.value] = by.get(res.status.value, 0) + 1
        print(f"generated: {len(outs)} requests, "
              f"{sum(len(o) for o in outs)} tokens, "
              f"peak cache blocks {stats.peak_cache_blocks} "
              f"({stats.peak_cache_bytes / 2**20:.2f} MiB)")
        print(f"lifecycle: {by} | preempted {stats.preempted} "
              f"restored {stats.restored} shed {stats.shed} "
              f"timeouts {stats.timeouts} | swap peak "
              f"{stats.swap_peak_blocks} blocks | goodput "
              f"{stats.goodput_tok_per_s:.1f} tok/s")
        print(f"latency: ttft p50 {stats.ttft_p50 * 1e3:.1f}ms "
              f"p99 {stats.ttft_p99 * 1e3:.1f}ms | "
              f"tpot p50 {stats.tpot_p50 * 1e3:.2f}ms "
              f"p99 {stats.tpot_p99 * 1e3:.2f}ms | "
              f"queue wait p50 {stats.queue_wait_p50 * 1e3:.1f}ms "
              f"p99 {stats.queue_wait_p99 * 1e3:.1f}ms")
    else:
        if args.kv_dtype != "native":
            ap.error("--kv-dtype applies to the paged engine (--paged)")
        eng = ServeEngine(cfg, params, max_len=max_len)
        toks, stats = eng.generate(prompts,
                                   max_new_tokens=max(budgets),
                                   temperature=args.temperature,
                                   top_k=args.top_k, top_p=args.top_p,
                                   extra_inputs=extra)
        print("generated:", toks.shape)
    print(f"compile {stats.compile_s:.3f}s prefill {stats.prefill_s:.3f}s "
          f"decode {stats.decode_s:.3f}s ({stats.tok_per_s:.1f} tok/s)")
    if args.metrics:
        obs.get_metrics().dump_jsonl(args.metrics)
        print(f"metrics: {args.metrics}")
    if args.trace:
        obs.export(args.trace)
        print(f"trace: {args.trace} (open in ui.perfetto.dev or "
              f"chrome://tracing)")


if __name__ == "__main__":
    main()
