"""Serving launcher: batched generation with the ServeEngine.

CPU smoke:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --reduced \
      --batch 4 --new-tokens 16
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.models import get_model, reduced
from repro.serve import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seq-shard", action="store_true",
                    help="sequence-sharded prefill: S over 'model', ring "
                         "attention for full layers (DESIGN.md §8)")
    ap.add_argument("--attn-impl", choices=["auto", "dense", "ring"],
                    default="auto",
                    help="attention implementation selection "
                         "(PerfFlags.attn_impl)")
    args = ap.parse_args()

    if args.seq_shard or args.attn_impl != "auto":
        from repro.perf_flags import set_flags
        set_flags(seq_shard=args.seq_shard, attn_impl=args.attn_impl)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params,
                      max_len=args.prompt_len + args.new_tokens + 8)

    rng = np.random.RandomState(0)
    prompts = [list(rng.randint(1, cfg.vocab, args.prompt_len))
               for _ in range(args.batch)]
    extra = {}
    if cfg.encoder_layers:
        extra["frames"] = np.asarray(rng.randn(
            args.batch, cfg.frontend_tokens, cfg.frontend_dim), np.float32)
    elif cfg.frontend_tokens:
        extra["patches"] = np.asarray(rng.randn(
            args.batch, cfg.frontend_tokens, cfg.frontend_dim), np.float32)
    toks, stats = eng.generate(prompts, max_new_tokens=args.new_tokens,
                               temperature=args.temperature,
                               extra_inputs=extra)
    print("generated:", toks.shape)
    print(f"prefill {stats.prefill_s:.3f}s decode {stats.decode_s:.3f}s "
          f"({stats.tok_per_s:.1f} tok/s)")


if __name__ == "__main__":
    main()
