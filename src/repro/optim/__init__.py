from .optimizers import sgd, sgd_momentum, adam, adamw, apply_updates
from .schedule import constant, cosine, warmup_cosine

__all__ = ["sgd", "sgd_momentum", "adam", "adamw", "apply_updates",
           "constant", "cosine", "warmup_cosine"]
