"""Optimizers (MXNet §2.4 training module) as pure (init, update) pairs.

Every optimizer keeps fp32 master state shaped/sharded like the params.
The SGD-momentum update can route through the fused Pallas kernel
(``use_pallas=True``) — the KVStore updater as a mutating big-op.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: callable
    update: callable  # (grads, state, params) -> (updates_applied_params, state)


def _f32_like(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def sgd(lr=1e-2, weight_decay=0.0):
    def init(params):
        return {"step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr_scale=1.0):
        def upd(p, g):
            g32 = g.astype(jnp.float32) + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * lr_scale * g32).astype(p.dtype)
        return (jax.tree.map(upd, params, grads),
                {"step": state["step"] + 1})
    return Optimizer(init, update)


def sgd_momentum(lr=1e-2, mu=0.9, weight_decay=1e-4, use_pallas=False):
    def init(params):
        return {"mom": _f32_like(params), "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr_scale=1.0):
        if use_pallas:
            from repro.kernels.ops import sgd_momentum as fused

            def upd(p, g, m):
                return fused(p, g, m, lr=lr * lr_scale, mu=mu,
                             weight_decay=weight_decay)
        else:
            def upd(p, g, m):
                g32 = (g.astype(jnp.float32)
                       + weight_decay * p.astype(jnp.float32))
                m = mu * m + g32
                return (p.astype(jnp.float32)
                        - lr * lr_scale * m).astype(p.dtype), m
        pairs = jax.tree.map(upd, params, grads, state["mom"])
        new_p = jax.tree.map(lambda t: t[0], pairs,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda t: t[1], pairs,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_p, {"mom": new_m, "step": state["step"] + 1}
    return Optimizer(init, update)


def adam(lr=1e-3, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0):
    def init(params):
        return {"m": _f32_like(params), "v": _f32_like(params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr_scale=1.0):
        t = state["step"] + 1
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)

        def upd(p, g, m, v):
            g32 = g.astype(jnp.float32)
            if weight_decay:
                g32 = g32 + weight_decay * p.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g32
            v = b2 * v + (1 - b2) * g32 * g32
            step = lr * lr_scale * (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            return (p.astype(jnp.float32) - step).astype(p.dtype), m, v
        tri = jax.tree.map(upd, params, grads, state["m"], state["v"])
        is_l = lambda x: isinstance(x, tuple)
        return (jax.tree.map(lambda t: t[0], tri, is_leaf=is_l),
                {"m": jax.tree.map(lambda t: t[1], tri, is_leaf=is_l),
                 "v": jax.tree.map(lambda t: t[2], tri, is_leaf=is_l),
                 "step": t})
    return Optimizer(init, update)


def adamw(lr=1e-3, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.01):
    return adam(lr=lr, b1=b1, b2=b2, eps=eps, weight_decay=weight_decay)


def apply_updates(optimizer: Optimizer, grads, state, params, lr_scale=1.0):
    return optimizer.update(grads, state, params, lr_scale=lr_scale)
