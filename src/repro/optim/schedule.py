"""Learning-rate schedules (pure functions of the step)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(value=1.0):
    return lambda step: jnp.asarray(value, jnp.float32)


def cosine(total_steps, final_frac=0.1):
    def f(step):
        t = jnp.clip(step / total_steps, 0.0, 1.0)
        return final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return f


def warmup_cosine(warmup_steps, total_steps, final_frac=0.1):
    cos = cosine(max(total_steps - warmup_steps, 1), final_frac)

    def f(step):
        w = jnp.clip(step / max(warmup_steps, 1), 0.0, 1.0)
        return jnp.where(step < warmup_steps, w, cos(step - warmup_steps))
    return f
